"""Regenerate tests/golden/trajectories.json (ISSUE 3).

    PYTHONPATH=src python tools/make_golden_trajectories.py

The fixtures pin the solvers' *swap decisions* on seeded instances: any
kernel or solver refactor that silently changes a trajectory fails the
golden suite loudly, even if the final objective barely moves. Every
instance lives on a dyadic grid with power-of-two row counts, so all
solver arithmetic (sums, means) is exact in f32 — the committed numbers
are reproducible bit-for-bit across machines and jax versions, not
accidents of summation order.

Only rerun this tool when a trajectory change is *intended*; commit the
diff together with the change that caused it.
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import sampling, trace  # noqa: E402

OUT = ROOT / "tests" / "golden" / "trajectories.json"

# (name, spec) pairs; specs are replayed verbatim by the golden test.
MATRIX_CASES = [
    ("matrix_small", dict(seed=0, n=64, m=64, k=4, quant=64)),
    ("matrix_rect", dict(seed=1, n=128, m=32, k=6, quant=64)),
    ("matrix_ties", dict(seed=2, n=64, m=64, k=5, quant=4)),
]
E2E_CASES = [
    ("e2e_nniw_l1", dict(seed=3, n=128, p=4, k=5, m=16, variant="nniw",
                         metric="l1")),
    ("e2e_unif_chebyshev", dict(seed=4, n=64, p=6, k=4, m=16,
                                variant="unif", metric="chebyshev")),
]
# Matrix-free cases (ISSUE 4): the block-free sweep replayed through
# trace_matrix_free. The tool asserts the trajectory equals the block
# path's before committing, so the fixture pins both the matrix-free
# decisions AND the cross-path identity.
MF_CASES = [
    ("matrix_free_nniw_l1", dict(seed=5, n=128, p=4, k=5, m=16,
                                 variant="nniw", metric="l1")),
    ("matrix_free_debias_sqeuclidean", dict(seed=6, n=64, p=8, k=4, m=16,
                                            variant="debias",
                                            metric="sqeuclidean")),
]
# Pruned-sweep cases (ISSUE 6): the bound-pruned sweep replayed through
# trace_pruned. Generation asserts the trajectory equals BOTH the
# matrix-free and the block trace — the committed fixture pins the
# three-way cross-path identity, not just the pruned decisions. prune_m
# is left at its default (m // 8) so the fixture also pins the default
# phase-1 subsample geometry.
PRUNED_CASES = [
    ("pruned_nniw_l1", dict(seed=7, n=128, p=4, k=5, m=32,
                            variant="nniw", metric="l1")),
    ("pruned_debias_l2", dict(seed=8, n=64, p=8, k=4, m=32,
                              variant="debias", metric="l2")),
]


def matrix_instance(spec):
    rng = np.random.default_rng(spec["seed"])
    d = rng.integers(0, 8 * spec["quant"],
                     size=(spec["n"], spec["m"])).astype(np.float32)
    d = d / np.float32(spec["quant"])
    init = rng.choice(spec["n"], size=spec["k"], replace=False)
    return jnp.asarray(d), jnp.asarray(init)


def e2e_instance(spec):
    rng = np.random.default_rng(spec["seed"])
    x = rng.integers(0, 8, size=(spec["n"], spec["p"])).astype(np.float32)
    batch = sampling.build_batch(jax.random.PRNGKey(spec["seed"]),
                                 jnp.asarray(x), spec["m"],
                                 variant=spec["variant"],
                                 metric=spec["metric"], backend="ref")
    init = jnp.asarray(rng.choice(spec["n"], size=spec["k"], replace=False))
    return batch.d, init


def matrix_free_instance(spec):
    """(x, block-free batch, init) for a matrix-free golden case — the
    same dyadic-grid recipe as e2e_instance, block never built."""
    rng = np.random.default_rng(spec["seed"])
    x = jnp.asarray(
        rng.integers(0, 8, size=(spec["n"], spec["p"])).astype(np.float32))
    batch = sampling.build_batch(jax.random.PRNGKey(spec["seed"]), x,
                                 spec["m"], variant=spec["variant"],
                                 metric=spec["metric"], backend="ref",
                                 materialize=False)
    init = jnp.asarray(rng.choice(spec["n"], size=spec["k"], replace=False))
    return x, batch, init


def record(tr):
    return {
        "swaps": [list(s) for s in tr.swaps],
        "medoids": np.asarray(tr.result.medoid_idx).tolist(),
        "n_swaps": int(tr.result.n_swaps),
        "objective": float(tr.result.est_objective),
        "converged": bool(tr.result.converged),
    }


def main():
    cases = []
    for name, spec in MATRIX_CASES:
        d, init = matrix_instance(spec)
        cases.append({
            "name": name, "kind": "matrix", "spec": spec,
            "init": np.asarray(init).tolist(),
            "batched": record(trace.trace_batched(d, init, backend="ref")),
            "eager": record(trace.trace_eager(d, init)),
        })
        print(f"{name}: {cases[-1]['batched']['n_swaps']} batched / "
              f"{cases[-1]['eager']['n_swaps']} eager swaps")
    for name, spec in E2E_CASES:
        d, init = e2e_instance(spec)
        cases.append({
            "name": name, "kind": "e2e", "spec": spec,
            "init": np.asarray(init).tolist(),
            "batched": record(trace.trace_batched(d, init, backend="ref")),
        })
        print(f"{name}: {cases[-1]['batched']['n_swaps']} batched swaps")
    for name, spec in MF_CASES:
        x, batch, init = matrix_free_instance(spec)
        tr = trace.trace_matrix_free(x, batch.idx, batch.weights, init,
                                     metric=spec["metric"],
                                     debias=(spec["variant"] == "debias"),
                                     backend="ref")
        # Cross-path identity, enforced at generation time: the committed
        # matrix-free trajectory IS the block trajectory.
        blk = sampling.build_batch(jax.random.PRNGKey(spec["seed"]), x,
                                   spec["m"], variant=spec["variant"],
                                   metric=spec["metric"], backend="ref")
        blk_tr = trace.trace_batched(blk.d, init, backend="ref")
        assert tr.swaps == blk_tr.swaps, name
        cases.append({
            "name": name, "kind": "matrix_free", "spec": spec,
            "init": np.asarray(init).tolist(),
            "batched": record(tr),
        })
        print(f"{name}: {cases[-1]['batched']['n_swaps']} matrix-free swaps")
    for name, spec in PRUNED_CASES:
        x, batch, init = matrix_free_instance(spec)
        tr = trace.trace_pruned(x, batch.idx, batch.weights, init,
                                metric=spec["metric"],
                                debias=(spec["variant"] == "debias"),
                                backend="ref")
        # Three-way cross-path identity, enforced at generation time: the
        # committed pruned trajectory IS the matrix-free trajectory IS
        # the block trajectory.
        mf_tr = trace.trace_matrix_free(x, batch.idx, batch.weights, init,
                                        metric=spec["metric"],
                                        debias=(spec["variant"] == "debias"),
                                        backend="ref")
        blk = sampling.build_batch(jax.random.PRNGKey(spec["seed"]), x,
                                   spec["m"], variant=spec["variant"],
                                   metric=spec["metric"], backend="ref")
        blk_tr = trace.trace_batched(blk.d, init, backend="ref")
        assert tr.swaps == mf_tr.swaps == blk_tr.swaps, name
        cases.append({
            "name": name, "kind": "pruned", "spec": spec,
            "init": np.asarray(init).tolist(),
            "batched": record(tr),
        })
        print(f"{name}: {cases[-1]['batched']['n_swaps']} pruned swaps")
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps({"format": 1, "cases": cases}, indent=1)
                   + "\n")
    print(f"wrote {len(cases)} cases to {OUT}")


if __name__ == "__main__":
    main()
