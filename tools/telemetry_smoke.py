"""End-to-end telemetry smoke (PR 10, CI lane): one checkpointed solve
plus one serving session with the full telemetry stack on, then check
every observability artifact the stack promises:

  * the Chrome trace file is valid JSON and carries the solve/sweep,
    solve/checkpoint_write, and serve/micro_batch spans;
  * the Prometheus scrape — fetched over HTTP from the engine's
    ``serve_metrics()`` endpoint, not just rendered in-process —
    contains the sweep, checkpoint, quarantine, and refit series the
    acceptance criteria name;
  * solve trajectory and serving labels are bitwise identical to the
    telemetry-off paths (telemetry observes, never steers).

Run:  PYTHONPATH=src python tools/telemetry_smoke.py
Exits non-zero on any failed check (assert), so CI can gate on it.
"""
from __future__ import annotations

import json
import sys
import tempfile
import urllib.request

import jax
import numpy as np


def main() -> int:
    from repro.core import MedoidSelector, solver
    from repro.monitoring import (MetricsRegistry, SpanTracer, Telemetry)
    from repro.serving import AssignmentEngine

    tel = Telemetry(MetricsRegistry(), SpanTracer())
    rng = np.random.default_rng(0)
    x = rng.standard_normal((512, 16)).astype(np.float32)
    key = jax.random.PRNGKey(0)

    # -- solve: checkpointed, telemetry on, trajectory bitwise-pinned --
    with tempfile.TemporaryDirectory() as ckdir:
        res_on, _, report = solver.one_batch_pam(
            key, x, 4, m=64, backend="ref", telemetry=tel,
            checkpoint_dir=ckdir, ckpt_every=1, return_report=True)
    res_off = solver.one_batch_pam(key, x, 4, m=64, backend="ref")[0]
    assert np.array_equal(np.asarray(res_on.medoid_idx),
                          np.asarray(res_off.medoid_idx)), \
        "telemetry='on' steered the solve trajectory"
    assert report.metrics is not None and report.metrics["sweeps"] > 0
    assert report.metrics["checkpoint_writes"] > 0
    print(f"solve OK: {report.metrics['sweeps']} sweeps, "
          f"{report.metrics['checkpoint_writes']} checkpoint writes")

    # -- serve: quarantine + refit + scrape endpoint ------------------
    sel = MedoidSelector(k=4, metric="l1", backend="ref")
    sel.fit(x)
    eng = AssignmentEngine.from_selector(
        sel, micro_batch=128, auto_refit=False, validate="cheap",
        telemetry=tel)
    eng_off = AssignmentEngine.from_selector(
        sel, micro_batch=128, auto_refit=False, validate="cheap")
    q = x[:256].copy()
    q[7] = np.nan                              # one quarantined row
    labels, d1 = eng.assign(q)
    l_off, d_off = eng_off.assign(q)
    assert np.array_equal(labels, l_off) and np.array_equal(
        d1, d_off, equal_nan=True), \
        "telemetry='on' steered the serving labels"
    assert eng.refit_now(x[256:], wait=True), "smoke refit did not run"
    print(f"serve OK: {labels.shape[0]} rows served, refit done "
          f"(medoid v{eng.medoid_version})")

    # -- the HTTP scrape ----------------------------------------------
    srv = eng.serve_metrics()
    try:
        with urllib.request.urlopen(srv.url, timeout=10) as resp:
            assert resp.status == 200
            scrape = resp.read().decode()
    finally:
        eng.close()
        eng_off.close()
    for series in ("solve_sweeps_total", "solve_sweep_seconds",
                   "solve_checkpoint_writes_total",
                   "solve_checkpoint_write_seconds",
                   "serving_quarantined_rows_total",
                   "serving_refit_attempts_total",
                   "serving_micro_batch_seconds", "serving_queries_total"):
        assert series in scrape, f"scrape is missing the {series} series"
    qline = [ln for ln in scrape.splitlines()
             if ln.startswith("serving_quarantined_rows_total")][0]
    assert qline.endswith(" 1"), f"expected 1 quarantined row: {qline!r}"
    assert 'serving_refit_attempts_total{outcome="success"} 1' in scrape
    print(f"scrape OK: {len(scrape.splitlines())} exposition lines "
          f"from {srv.url}")

    # -- the Chrome trace ---------------------------------------------
    with tempfile.TemporaryDirectory() as td:
        path = tel.write_chrome_trace(f"{td}/trace.json")
        doc = json.load(open(path))            # valid, loadable JSON
    names = {e["name"] for e in doc["traceEvents"]}
    for span in ("solve", "solve/sweep", "solve/checkpoint_write",
                 "serve/micro_batch", "serve/refit"):
        assert span in names, f"trace is missing the {span} span"
    assert all(e["ph"] in ("X", "i") for e in doc["traceEvents"])
    print(f"trace OK: {len(doc['traceEvents'])} events, "
          f"{len(names)} distinct spans")
    print("telemetry smoke: ALL OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
